"""ServeSession: continuous batching over the resident superstep loop.

The drain-batch driver inherits TOTEM's bulk-synchronous pathology: a
Q-batch occupies the engine until its *slowest* query converges, so one
deep query taxes Q-1 shallow ones.  Continuous batching is the
LLM-serving fix applied to BSP: keep ONE resident compiled loop running
and, at every chunk boundary (``run_batched_chunked``'s windows), compact
finished queries out of the ``[Q, Pl, v_max]`` state via their per-query
finished votes, harvest their results, and admit new queries from the
stream into the freed slots.  The slot count Q stays static — occupancy
is a host-side mask — so nothing retraces: the swap is one static-shape
jit (``core.bsp._slot_swap``) and the chunk jit never sees a new shape.

``ServeSession`` is the one serving API.  It subsumes the four historical
drivers as composable options:

===========================  ==========================================
driver                       session spelling
===========================  ==========================================
``serve``                    ``ServeSession(engine, alg)`` + drain()
``serve_depth_bucketed``     ``scheduler="depth", depth_key=...``
``serve_mutating``           dynamic engine + ``session.mutate(batch)``
``serve_fault_tolerant``     ``failures.serve_with_restarts`` +
                             ``quarantine``/``step_with_fallback``
===========================  ==========================================

Protocol: ``submit(queries)`` admits work (bounded by ``queue_capacity``,
rejects-with-reason beyond it), ``step()`` advances one chunk window
(checkpointable granularity), ``drain()`` runs the resident loop until
the queue and every slot are empty, ``poll()`` pops completed results.
``snapshot``/``restore`` persist the full serving carry — vertex state,
votes, per-slot step frames, occupancy mask, per-slot query ids, pending
queue, completed results — so a restart resumes mid-refill.

Correctness contract (pinned by tests/test_continuous.py): every
completed query's result is **bitwise identical** to the same query run
through drain-batch ``run_batched``, on every backend and device count.
The mechanism is the step-frame translation of
``algorithms/continuous.py``: a slot refilled at global step ``s0`` seeds
its program state translated by ``s0`` and the harvest translates back.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.runtime.sla import AdmissionController, QuarantinePolicy


def _cache_size(fn) -> int:
    try:
        return fn._cache_size()
    except AttributeError:
        return 0


class ServeSession:
    """One resident engine continuously serving a query stream.

    Parameters
    ----------
    engine:
        A ``BSPEngine`` or ``DistributedBSPEngine`` (static or dynamic).
    alg:
        Algorithm name with a continuous form (``bfs``/``sssp``) — others
        raise the actionable error from :func:`continuous_form`.
    slots:
        The static query-batch width Q.  Compiled once; occupancy varies.
    chunk:
        Supersteps per window — the refill (and checkpoint) granularity.
    queue_capacity:
        Admission bound; ``submit`` beyond it rejects with a reason.
    deadline_ms:
        Per-query SLA; completions past it are counted in ``sla()``.
    quarantine:
        Optional :class:`QuarantinePolicy` scanned at every boundary; a
        quarantined slot is freed for the next tenant in the same window.
    scheduler:
        ``"fifo"`` (arrival order) or ``"depth"`` (admit shallow-first by
        ``depth_key(source)`` — see ``graph_serve.estimate_depth_order``).
    certifier:
        Optional :class:`repro.runtime.verify.ResultCertifier` bound to the
        *current* graph: every harvested result is certified before
        completing.  A failed verdict triggers the recompute-once policy —
        the trusted NumPy reference answer replaces the corrupt result; if
        even that fails certification the query is quarantined with reason
        ``"certification"``.
    monitor:
        Optional :class:`repro.runtime.verify.InvariantMonitor` observed at
        every window boundary (threaded through ``engine.execute``); fired
        windows are counted in the report.
    """

    def __init__(self, engine, alg: str, *, slots: int, chunk: int = 2,
                 queue_capacity: Optional[int] = None,
                 deadline_ms: Optional[float] = None,
                 quarantine: Optional[QuarantinePolicy] = None,
                 scheduler: str = "fifo",
                 depth_key: Optional[Callable[[int], float]] = None,
                 certifier=None, monitor=None):
        from repro.algorithms.continuous import continuous_form

        if slots < 1:
            raise ValueError(f"slots must be >= 1, got {slots}")
        if scheduler not in ("fifo", "depth"):
            raise ValueError(f"scheduler must be 'fifo' or 'depth', "
                             f"got {scheduler!r}")
        if scheduler == "depth" and depth_key is None:
            raise ValueError(
                "scheduler='depth' needs depth_key(source) -> sort key "
                "(e.g. lambda s: -g.out_degrees()[s]); pass it or use "
                "scheduler='fifo'")
        self.engine = engine
        self.alg = alg
        self.form = continuous_form(alg)
        self.slots = int(slots)
        self.chunk = int(chunk)
        self.deadline_ms = deadline_ms
        self.quarantine = quarantine
        self.scheduler = scheduler
        self.depth_key = depth_key
        self.certifier = certifier
        self.monitor = monitor
        self.certified_ok = 0
        self.recomputed = 0
        self.certify_failures: List[dict] = []
        self.admission = AdmissionController(
            queue_capacity if queue_capacity is not None else (1 << 30))

        # occupancy: host-side, never traced
        self.occupied = np.zeros(self.slots, bool)
        self.slot_query = np.full(self.slots, -1, np.int64)
        self.slot_source = np.zeros(self.slots, np.int64)
        self.slot_step0 = np.zeros(self.slots, np.int64)
        self.slot_refills = np.zeros(self.slots, np.int64)

        # resident-loop carry (None until primed)
        self._state = None
        self._fin = None
        self._steps_q = None
        self._step = 0

        self.windows = 0
        self.refills = 0
        self.monitors_fired = 0
        self._next_qid = 0
        self._qsource: Dict[int, int] = {}
        self._qdeadline: Dict[int, Optional[float]] = {}
        self._submit_t: Dict[int, float] = {}
        self._completed: Dict[int, np.ndarray] = {}
        self._completed_steps: Dict[int, int] = {}
        self._latency_ms: Dict[int, float] = {}
        self.quarantined_qids: set = set()
        self.sla_misses = 0

        # zero-retrace accounting: baseline resets on warmup events (first
        # window, first refill) and legitimate dynamic recompiles
        # (compaction rebinds), then any cache growth is a retrace.
        self._entries0: Optional[int] = None
        self._warm_events: set = set()
        self._rebinds0 = getattr(engine, "dynamic_rebinds", 0)
        self._rebuilds0 = getattr(engine, "hybrid_dyn_rebuilds", 0)

    # ------------------------------------------------------------- admission

    def submit(self, queries: Sequence[int],
               deadline_ms: Optional[float] = None) -> List[Optional[int]]:
        """Offer sources to the admission queue; returns per-query ids
        (None where rejected — reasons in ``admission.rejected``)."""
        dl = deadline_ms if deadline_ms is not None else self.deadline_ms
        qids: List[Optional[int]] = []
        now = time.perf_counter()
        for src in np.asarray(queries).reshape(-1):
            qid = self._next_qid
            if self.admission.offer((qid, int(src)), dl):
                self._next_qid += 1
                self._qsource[qid] = int(src)
                self._qdeadline[qid] = dl
                self._submit_t[qid] = now
                qids.append(qid)
            else:
                qids.append(None)
        if self.scheduler == "depth":
            self.admission.reorder(lambda q: self.depth_key(q[1]))
        return qids

    # ------------------------------------------------------------ slot logic

    def _prime(self) -> None:
        """Initial admission: fill slots from the queue and build the
        step-0 carry.  Unfilled slots start finished (and unoccupied), so
        they cost nothing until the first refill claims them."""
        if self._state is not None:
            return
        entries = self.admission.take_entries(self.slots)
        sources = np.zeros(self.slots, np.int64)
        fin = np.ones(self.slots, bool)
        for slot, ((qid, src), _dl) in enumerate(entries):
            sources[slot] = src
            fin[slot] = False
            self.occupied[slot] = True
            self.slot_query[slot] = qid
            self.slot_source[slot] = src
            self.slot_step0[slot] = 0
        self._state = self.form.make_slot_state(
            self.engine.pg, sources, np.zeros(self.slots, np.int64))
        self._fin = fin
        self._steps_q = np.zeros(self.slots, np.int32)
        self._step = 0
        if self.quarantine is not None:
            self.quarantine.begin(self.slots)

    def _certified(self, result: np.ndarray, slot: int, qid: int,
                   step: int) -> np.ndarray:
        """Recompute-once-then-quarantine("certification") policy.

        A harvested result that fails its certifier is replaced by the
        trusted NumPy reference answer (one recompute — an O(V+E) sweep,
        not an engine rerun, so the jit caches stay untouched); if even
        the reference fails — certifier/graph mismatch, e.g. a stale
        certifier across a mutation — the query is quarantined."""
        source = int(self.slot_source[slot])
        verdict = self.certifier.certify(result, source=source)
        if verdict.ok:
            self.certified_ok += 1
            return result
        self.recomputed += 1
        ref = np.asarray(self.certifier.recompute(source))
        rec = dict(query=qid, source=source, step=step,
                   reason=verdict.reason(), recovered=True)
        if not self.certifier.certify(ref, source=source).ok:
            rec["recovered"] = False
            self.quarantined_qids.add(qid)
            if self.quarantine is not None:
                self.quarantine.quarantined.append(
                    {"query": qid, "reason": "certification",
                     "step": step, "steps_q": -1})
        self.certify_failures.append(rec)
        return ref

    def _harvest(self, snap: dict, done: np.ndarray) -> None:
        results = self.form.harvest(self.engine.pg, snap["state"],
                                    self.slot_step0)
        steps_q = snap["steps_q"]      # already per-slot (zeroed on refill)
        now = time.perf_counter()
        for slot in np.flatnonzero(done):
            qid = int(self.slot_query[slot])
            result = np.asarray(results[slot])
            if self.certifier is not None and qid not in self.quarantined_qids:
                result = self._certified(result, slot, qid, snap["step"])
            self._completed[qid] = result
            self._completed_steps[qid] = int(steps_q[slot])
            if qid in self._submit_t:
                lat = (now - self._submit_t[qid]) * 1e3
                self._latency_ms[qid] = lat
                dl = self._qdeadline.get(qid)
                if dl is not None and lat > dl:
                    self.sla_misses += 1
            self.occupied[slot] = False
            self.slot_query[slot] = -1

    def _boundary(self, snap: dict) -> dict:
        """The ``on_chunk`` hook: quarantine → harvest → refill.

        Order matters: the scan kills against the *pre-swap* state, the
        harvest reads the pre-swap state and per-slot counters, and only
        then do freed slots (converged, quarantined, or never-occupied)
        take new tenants — so a slot can be quarantined and handed to a
        fresh query at the same boundary.
        """
        out: dict = {}
        fin = np.asarray(snap["fin"]).copy()
        if self.quarantine is not None:
            kill = self.quarantine.scan(snap, ids=self.slot_query)
            if kill is not None:
                out["kill"] = kill
                fin |= kill
                for slot in np.flatnonzero(kill & self.occupied):
                    self.quarantined_qids.add(int(self.slot_query[slot]))
        done = fin & self.occupied
        if done.any():
            self._harvest(snap, done)
        free = np.flatnonzero(fin & ~self.occupied)
        entries = self.admission.take_entries(len(free))
        if entries:
            admit = np.zeros(self.slots, bool)
            sources = np.zeros(self.slots, np.int64)
            for slot, ((qid, src), _dl) in zip(free, entries):
                admit[slot] = True
                sources[slot] = src
                self.occupied[slot] = True
                self.slot_query[slot] = qid
                self.slot_source[slot] = src
                self.slot_step0[slot] = snap["step"]
                self.slot_refills[slot] += 1
            step0 = np.full(self.slots, snap["step"], np.int64)
            new_rows = self.form.make_slot_state(
                self.engine.pg, sources, step0)
            out["refill"] = (new_rows, admit)
            if self.quarantine is not None:
                self.quarantine.release(admit)
        return out

    def _absorb(self, state, steps_q, info) -> None:
        self._state = state
        self._steps_q = steps_q
        self._fin = info["finished"]
        self._step = info["final_step"]
        self.windows += info["chunks"]
        self.refills += info["refilled"]
        self.monitors_fired += info.get("monitors_fired", 0)
        self._account_retraces(info)

    def step(self) -> bool:
        """Advance one chunk window (the checkpoint/restart granularity).
        Returns False once drained."""
        self._prime()
        state, steps_q, info = self.engine.execute(
            self.form.program, self._state, chunk=self.chunk,
            on_chunk=self._boundary, max_chunks=1,
            start_step=self._step, fin=self._fin, steps_q=self._steps_q,
            monitor=self.monitor)
        self._absorb(state, steps_q, info)
        return not self.drained()

    def drain(self) -> dict:
        """Serve until queue and slots are empty through ONE
        ``engine.execute`` call — the resident-loop path (``step()`` is
        for drivers that need a host boundary per window).  Returns the
        session report."""
        self._prime()
        while not self.drained():
            state, steps_q, info = self.engine.execute(
                self.form.program, self._state, chunk=self.chunk,
                on_chunk=self._boundary,
                start_step=self._step, fin=self._fin, steps_q=self._steps_q,
                monitor=self.monitor)
            self._absorb(state, steps_q, info)
        return self.report()

    def drained(self) -> bool:
        return (not self.occupied.any()) and len(self.admission) == 0

    def poll(self) -> List[dict]:
        """Pop completed queries: ``{"query", "source", "result", "steps",
        "quarantined", "latency_ms"}`` per completion, submit order."""
        out = []
        for qid in sorted(self._completed):
            out.append(dict(
                query=qid, source=self._qsource.get(qid),
                result=self._completed[qid],
                steps=self._completed_steps.get(qid),
                quarantined=qid in self.quarantined_qids,
                latency_ms=self._latency_ms.get(qid)))
        self._completed = {}
        return out

    # ------------------------------------------------------------- mutations

    def mutate(self, batch) -> dict:
        """Apply one edge-mutation batch to the resident dynamic graph —
        in the same session that is continuously serving.  Applies at a
        window boundary (call between ``step()``s or between ``drain()``
        waves); in-flight traversals would otherwise straddle two graph
        versions and match neither drain-batch result."""
        dg = getattr(self.engine, "dg", None)
        if dg is None:
            raise ValueError(
                "session.mutate() needs a dynamic engine — build it as "
                "BSPEngine(DynamicGraph(g, parts, strategy)) (see "
                "docs/dynamic.md); a static-partition engine cannot "
                "absorb mutations")
        return dg.apply_mutations(batch)

    # ---------------------------------------------------- retrace accounting

    def _cache_entries(self) -> int:
        from repro.core import bsp

        total = _cache_size(bsp._slot_swap)
        chunk_jits = getattr(self.engine, "_chunk_jits", None)
        if chunk_jits is not None:                    # distributed
            return total + len(chunk_jits)
        if getattr(self.engine, "dg", None) is not None:
            return (total + _cache_size(bsp._run_dyn_chunk_jit)
                    + _cache_size(bsp._run_dyn_hybrid_chunk_jit))
        return total + _cache_size(type(self.engine)._run_chunk)

    def _account_retraces(self, info) -> None:
        legit = False
        for event, seen in (("window", self.windows > 0),
                            ("refill", self.refills > 0)):
            if seen and event not in self._warm_events:
                self._warm_events.add(event)
                legit = True           # warmup compile, resets the baseline
        rebinds = getattr(self.engine, "dynamic_rebinds", 0)
        rebuilds = getattr(self.engine, "hybrid_dyn_rebuilds", 0)
        if rebinds != self._rebinds0 or rebuilds != self._rebuilds0:
            self._rebinds0, self._rebuilds0 = rebinds, rebuilds
            legit = True               # compaction rebind recompiles
        if legit or self._entries0 is None:
            self._entries0 = self._cache_entries()

    def retraces(self) -> int:
        """Compile-cache growth since warmup, net of legitimate events —
        the serving contract is 0."""
        if self._entries0 is None:
            return 0
        return self._cache_entries() - self._entries0

    # -------------------------------------------------------------- reports

    def report(self) -> dict:
        lat = sorted(self._latency_ms.values())

        def pct(p):
            return (float(np.percentile(lat, p, method="nearest"))
                    if lat else None)

        return dict(
            algorithm=self.alg, slots=self.slots, chunk=self.chunk,
            submitted=self._next_qid,
            completed=len(self._completed_steps),
            pending=len(self.admission),
            rejected=len(self.admission.rejected),
            windows=self.windows, refills=self.refills,
            min_slot_refills=int(self.slot_refills.min()),
            max_slot_refills=int(self.slot_refills.max()),
            retraces=self.retraces(),
            quarantined=sorted(self.quarantined_qids),
            sla_misses=self.sla_misses,
            certified_ok=self.certified_ok,
            recomputed=self.recomputed,
            certify_failed=list(self.certify_failures),
            monitors_fired=self.monitors_fired,
            latency_p50_ms=pct(50), latency_p99_ms=pct(99),
            final_step=int(self._step),
            backend=getattr(self.engine, "backend", None),
            engine=type(self.engine).__name__,
            # Honest serving capacity under tiered memory: only hbm_bytes
            # competes with other sessions for device residency; host_bytes
            # is streamed DRAM (0 when everything is resident).
            **self._residency())

    def _residency(self) -> dict:
        fn = getattr(self.engine, "residency_bytes", None)
        if fn is None:
            return {}
        r = fn()
        return dict(hbm_bytes=int(r["hbm_bytes"]),
                    host_bytes=int(r["host_bytes"]))

    # --------------------------------------------------- checkpoint/restore

    def _like_carry(self) -> dict:
        state = self.form.make_slot_state(
            self.engine.pg, np.zeros(self.slots, np.int64),
            np.zeros(self.slots, np.int64))
        return {"state": state,
                "fin": np.zeros(self.slots, bool),
                "steps_q": np.zeros(self.slots, np.int32)}

    def snapshot(self, manager, step: Optional[int] = None,
                 blocking: bool = True) -> None:
        """Persist the full serving carry.  Occupancy mask, per-slot query
        ids/step frames/refill counts, the pending queue, and completed
        results all ride along, so :meth:`restore` resumes *mid-refill*
        — not from the initial admission."""
        self._prime()
        tree = {"carry": {"state": self._state, "fin": self._fin,
                          "steps_q": self._steps_q},
                "completed": {str(q): v for q, v in self._completed.items()}}
        extra = dict(
            step=int(self._step), windows=self.windows,
            refills=self.refills, next_qid=self._next_qid,
            occupied=self.occupied.tolist(),
            slot_query=self.slot_query.tolist(),
            slot_source=self.slot_source.tolist(),
            slot_step0=self.slot_step0.tolist(),
            slot_refills=self.slot_refills.tolist(),
            pending=[[int(qid), int(src),
                      None if dl is None else float(dl)]
                     for (qid, src), dl in list(self.admission._queue)],
            qsource={str(q): int(s) for q, s in self._qsource.items()},
            completed_steps={str(q): int(s)
                             for q, s in self._completed_steps.items()},
            quarantined=sorted(self.quarantined_qids))
        manager.save_tree(step if step is not None else self.windows,
                          tree, extra=extra, blocking=blocking)

    def restore(self, manager, step: Optional[int] = None) -> int:
        step = step if step is not None else manager.latest_step()
        if step is None:
            raise FileNotFoundError(f"no session snapshot in {manager.dir}")
        extra = manager.manifest_extra(step)
        n = self.engine.pg.num_vertices
        like = {"carry": self._like_carry(),
                "completed": {q: np.zeros(n, np.float32)
                              for q in extra["completed_steps"]}}
        _, tree = manager.restore_tree(like, step)
        self._state = tree["carry"]["state"]
        self._fin = np.asarray(tree["carry"]["fin"], bool)
        self._steps_q = np.asarray(tree["carry"]["steps_q"], np.int32)
        self._step = int(extra["step"])
        self.windows = int(extra["windows"])
        self.refills = int(extra["refills"])
        self._next_qid = int(extra["next_qid"])
        self.occupied = np.asarray(extra["occupied"], bool)
        self.slot_query = np.asarray(extra["slot_query"], np.int64)
        self.slot_source = np.asarray(extra["slot_source"], np.int64)
        self.slot_step0 = np.asarray(extra["slot_step0"], np.int64)
        self.slot_refills = np.asarray(extra["slot_refills"], np.int64)
        self._qsource = {int(q): int(s)
                         for q, s in extra["qsource"].items()}
        self._completed = {int(q): np.asarray(v)
                           for q, v in tree["completed"].items()}
        self._completed_steps = {int(q): int(s)
                                 for q, s in extra["completed_steps"].items()}
        self.quarantined_qids = set(extra["quarantined"])
        self.admission._queue.clear()
        for qid, src, dl in extra["pending"]:
            self.admission._queue.append(((int(qid), int(src)), dl))
            self._qsource[int(qid)] = int(src)
            self._qdeadline[int(qid)] = dl
        if self.quarantine is not None:
            self.quarantine.begin(self.slots)
        # a restored session recompiles (possibly a rebuilt engine): reset
        # the retrace baseline to the post-restore warmup
        self._entries0 = None
        self._warm_events = set()
        return step

    # ----------------------------------------------------------- degradation

    def handoff(self, other: "ServeSession") -> None:
        """Copy this session's carry + occupancy into ``other`` (a session
        over a different engine on the same graph) — the degradation path.
        The fallback resumes the *refilled* occupancy, mid-stream."""
        other._state = (None if self._state is None
                        else {k: np.asarray(v)
                              for k, v in self._state.items()})
        other._fin = None if self._fin is None else np.asarray(self._fin)
        other._steps_q = (None if self._steps_q is None
                          else np.asarray(self._steps_q))
        other._step = self._step
        other.windows, other.refills = self.windows, self.refills
        other._next_qid = self._next_qid
        other.occupied = self.occupied.copy()
        other.slot_query = self.slot_query.copy()
        other.slot_source = self.slot_source.copy()
        other.slot_step0 = self.slot_step0.copy()
        other.slot_refills = self.slot_refills.copy()
        other._qsource = dict(self._qsource)
        other._qdeadline = dict(self._qdeadline)
        other._submit_t = dict(self._submit_t)
        other._completed = dict(self._completed)
        other._completed_steps = dict(self._completed_steps)
        other._latency_ms = dict(self._latency_ms)
        other.quarantined_qids = set(self.quarantined_qids)
        other.admission = self.admission

    def step_with_fallback(self, fallback: "ServeSession", ladder) -> bool:
        """One window through a :class:`DegradationLadder`: retry this
        session's engine, then hand the carry to ``fallback`` (reference
        backend) and continue there.  This is how the ladder threads the
        session API — thunks close over sessions, and the handoff carries
        the refilled slot occupancy across the downgrade."""
        def fb():
            self.handoff(fallback)
            return fallback.step()

        return ladder.run(self.step, fb,
                          label=f"window{self.windows}:{self.alg}")


def drain_reference(engine, alg: str, sources, slots: int) -> np.ndarray:
    """The parity oracle: run ``sources`` through plain drain-batch
    ``run_batched`` in fixed batches of ``slots``; returns [len, n]
    results.  Every session completion must equal its row bitwise."""
    from repro.launch.graph_serve import run_query_batch

    sources = np.asarray(sources).reshape(-1)
    out = []
    for i in range(0, len(sources), slots):
        batch = np.resize(sources[i:i + slots], slots)
        out.append(run_query_batch(engine, alg, batch)[
            : min(slots, len(sources) - i)])
    return np.concatenate(out, axis=0)
