"""Straggler detection: per-step timing watchdog.

At 1000+ nodes the slowest worker sets the step time (the paper's makespan,
Eq. 2, applied to the fleet).  The watchdog keeps an EWMA + variance of step
durations and flags steps (or, multi-host, workers — the per-host hook is
``report``) that exceed ``threshold`` standard deviations.  Mitigation hooks:
skip-slow-data-shard, checkpoint-and-replace-node, or just alerting; the
driver decides via the callback.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional


@dataclasses.dataclass
class StepWatchdog:
    alpha: float = 0.1                 # EWMA factor
    threshold: float = 3.0             # flag at mean + threshold·std
    warmup_steps: int = 5              # ignore compile/first steps
    on_straggler: Optional[Callable[[int, float, float], None]] = None
    # absolute ceiling: flag regardless of warmup/EWMA (serving drivers use
    # this as the checkpoint-now trigger — a step this slow may be a dying
    # worker, snapshot before it takes the batch down)
    hard_limit_s: Optional[float] = None

    _mean: float = 0.0
    _var: float = 0.0
    _count: int = 0
    _start: float = 0.0
    stragglers: List[int] = dataclasses.field(default_factory=list)

    def start(self):
        self._start = time.perf_counter()

    def stop(self, step: int) -> bool:
        """Returns True if this step was flagged as a straggler."""
        dur = time.perf_counter() - self._start
        return self.report(step, dur)

    def report(self, step: int, dur: float) -> bool:
        self._count += 1
        hard = self.hard_limit_s is not None and dur > self.hard_limit_s
        if self._count <= self.warmup_steps:
            self._mean = dur if self._count == 1 else \
                self._mean + (dur - self._mean) / self._count
            if hard:
                self.stragglers.append(step)
                if self.on_straggler:
                    self.on_straggler(step, dur, self._mean)
            return hard
        std = max(self._var ** 0.5, 1e-9)
        flagged = hard or (dur > self._mean + self.threshold * std
                           and dur > 1.5 * self._mean)
        if flagged:
            self.stragglers.append(step)
            if self.on_straggler:
                self.on_straggler(step, dur, self._mean)
        # EWMA update; flagged steps contribute with dampened weight so a
        # single spike barely moves the mean but a persistent regime change
        # (e.g. a permanently slower replacement node) is eventually absorbed
        # instead of being flagged forever.
        a = self.alpha * (0.25 if flagged else 1.0)
        delta = dur - self._mean
        self._mean += a * delta
        self._var = (1 - a) * (self._var + a * delta * delta)
        return flagged

    @property
    def mean_step_s(self) -> float:
        return self._mean
