"""Self-certifying fixpoints: linear-time result certifiers + in-loop monitors.

A BSP fixpoint is expensive to compute but cheap to *certify*: once the
vertex program has converged, each algorithm's defining inequality can be
checked in one O(V+E) sweep over the CSR arrays, with no reference to how
the result was produced.  That asymmetry is the whole defense against
silent corruption — a bit-flip that survives the min/sum combine, the
exchange, checkpointing, and harvest still has to explain itself against
the graph.

Two layers live here, both pure NumPy (no JAX imports at module scope, so
the serving host loop can certify without touching device state):

* ``ResultCertifier`` — per-algorithm post-hoc certifiers.  Each returns a
  structured :class:`Verdict` (named checks with violation counts), never a
  bare bool, so quarantine records and drill reports can say *which*
  invariant a corrupted result broke.
* ``InvariantMonitor`` — an in-loop observer for ``run_batched_chunked``'s
  window snapshots: min-semiring monotonicity (state never increases across
  windows), semiring-aware finiteness, and frontier sanity (finished votes
  never regress, per-slot step counters advance by at most one chunk).

Certifier contracts (see docs/robustness.md "Silent faults"):

=========  ==================================================================
bfs        ``level[src] == 0``; finite levels are non-negative integers; no
           edge spans more than one level (``level[v] <= level[u] + 1``);
           every finite non-source level has an in-edge parent at exactly
           ``level - 1``.
sssp       ``dist[src] == 0``; no relaxable edge
           (``dist[v] <= f32(dist[u] + w)``); every finite non-source
           distance is *witnessed* by some in-edge achieving it (rules out
           the all-zeros state, which no-relaxable-edge alone accepts).
cc         labels are integral vertex ids with ``label[v] <= v``; edge
           endpoints agree (run on the symmetrized graph); labels are
           root-fixed (``label[label[v]] == label[v]``).
pagerank   finite non-negative ranks; total mass in
           ``[(1-d) - tol, 1 + tol]`` (dangling vertices leak mass); one
           extra power-iteration step moves the vector by at most the
           ``2·d^k`` contraction bound.
bc         sampled pair-recomputation against the O(V+E) Brandes reference
           for the given source.
=========  ==================================================================
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "CheckResult", "Verdict", "ResultCertifier", "InvariantMonitor",
    "certify", "register_certifier", "registered_algorithms", "monitor_for",
]


# ---------------------------------------------------------------------------
# structured verdicts


@dataclasses.dataclass(frozen=True)
class CheckResult:
    """One named invariant check: how many violations, and where/why."""
    name: str
    ok: bool
    violations: int = 0
    detail: str = ""


@dataclasses.dataclass(frozen=True)
class Verdict:
    """Outcome of certifying one result vector against one graph."""
    algorithm: str
    ok: bool
    checks: Tuple[CheckResult, ...]

    def failed(self) -> List[CheckResult]:
        return [c for c in self.checks if not c.ok]

    def reason(self) -> str:
        """Comma-joined names of the violated checks ('' when ok)."""
        return ",".join(c.name for c in self.checks if not c.ok)

    def summary(self) -> dict:
        return {
            "algorithm": self.algorithm, "ok": self.ok,
            "failed": [dataclasses.asdict(c) for c in self.failed()],
        }


def _check(name: str, bad_mask, detail: str = "") -> CheckResult:
    bad = np.asarray(bad_mask)
    n_bad = int(bad.sum()) if bad.shape else int(bad)
    if n_bad and not detail:
        where = np.flatnonzero(np.atleast_1d(bad))[:4].tolist()
        detail = f"first offenders at {where}"
    return CheckResult(name=name, ok=n_bad == 0, violations=n_bad,
                       detail=detail)


# ---------------------------------------------------------------------------
# per-algorithm certifiers — each fn(g, result, source, **params) -> checks


_CERTIFIERS: Dict[str, Callable] = {}


def register_certifier(name: str):
    def deco(fn):
        _CERTIFIERS[name] = fn
        return fn
    return deco


def registered_algorithms() -> List[str]:
    return sorted(_CERTIFIERS)


def _in_edge_min(g, values: np.ndarray) -> np.ndarray:
    """Per-vertex min over in-edges of ``values[src] (+ already applied)``.

    ``values`` is per-*edge* (length E, ordered like ``g.col``); returns the
    min received by each destination vertex, inf where no in-edges.
    """
    best = np.full(g.num_vertices, np.inf, dtype=np.float64)
    np.minimum.at(best, g.col, values)
    return best


@register_certifier("bfs")
def _certify_bfs(g, level, source=None, **params):
    level = np.asarray(level, dtype=np.float64)
    fin = np.isfinite(level)
    checks = []
    if source is not None:
        checks.append(_check("source_zero", level[int(source)] != 0.0,
                             detail=f"level[{int(source)}]={level[int(source)]}"))
    checks.append(_check("integral_nonneg",
                         fin & ((level < 0) | (level != np.floor(level)))))
    src = params.get("_src")
    src = g.edge_sources() if src is None else src
    # No edge spans more than one level: a reached u must not leave v at a
    # level beyond u+1 (an unreached v with a reached parent violates too —
    # inf > level[u]+1).
    checks.append(_check("edge_span",
                         np.isfinite(level[src]) & (level[g.col] > level[src] + 1)))
    # Every finite non-source level has a parent at exactly level-1.
    best = _in_edge_min(g, level[src])
    needs = fin & (level > 0)
    if source is not None:
        needs[int(source)] = False
    checks.append(_check("parent_witness", needs & (best + 1 != level)))
    return checks


@register_certifier("sssp")
def _certify_sssp(g, dist, source=None, rtol=1e-5, atol=1e-5, **params):
    if g.weights is None:
        raise ValueError("sssp certifier needs an edge-weighted graph "
                         "(CSRGraph.weights is None)")
    dist = np.asarray(dist, dtype=np.float64)
    checks = []
    if source is not None:
        checks.append(_check("source_zero", dist[int(source)] != 0.0,
                             detail=f"dist[{int(source)}]={dist[int(source)]}"))
    src = params.get("_src")
    src = g.edge_sources() if src is None else src
    # Relaxation candidates exactly as the engine computes them: f32 sums.
    cand = (dist[src].astype(np.float32)
            + np.asarray(g.weights, dtype=np.float32)).astype(np.float64)
    tol = atol + rtol * np.where(np.isfinite(cand), np.abs(cand), 0.0)
    checks.append(_check("no_relaxable_edge", dist[g.col] > cand + tol))
    # Tight witness: each finite non-source dist is achieved by some in-edge
    # (kills the all-zeros state that no-relaxable-edge alone accepts).
    best = _in_edge_min(g, cand)
    needs = np.isfinite(dist)
    if source is not None:
        needs[int(source)] = False
    wtol = atol + rtol * np.where(np.isfinite(best), np.abs(best), 0.0)
    checks.append(_check("tight_witness", needs & ~(np.abs(best - dist) <= wtol)))
    return checks


@register_certifier("cc")
def _certify_cc(g, labels, source=None, **params):
    """Certify min-label CC.  ``g`` must be the symmetrized graph the
    propagation ran on (``repro.algorithms.cc.symmetrize``)."""
    lab = np.asarray(labels, dtype=np.float64)
    n = g.num_vertices
    ids = np.arange(n, dtype=np.float64)
    fin = np.isfinite(lab)
    checks = [
        _check("finite_integral",
               ~fin | (lab < 0) | (lab != np.floor(lab)) | (lab >= n)),
        _check("label_minimal", fin & (lab > ids)),
    ]
    src = params.get("_src")
    src = g.edge_sources() if src is None else src
    checks.append(_check("endpoint_agreement", lab[src] != lab[g.col]))
    # Labels are component roots: following the label once is a fixpoint.
    safe = np.where(fin, lab, 0).astype(np.int64)
    checks.append(_check("root_fixpoint", fin & (lab[safe] != lab)))
    return checks


@register_certifier("pagerank")
def _certify_pagerank(g, rank, source=None, num_iterations=20,
                      damping=0.85, tol=1e-3, **params):
    rank = np.asarray(rank, dtype=np.float64)
    n = g.num_vertices
    checks = [_check("finite_nonneg", ~np.isfinite(rank) | (rank < -1e-9))]
    # Mass conservation: dangling vertices leak (the engine drops their
    # rank), so total mass lives in [(1-d), 1] up to f32 accumulation noise.
    mass = float(rank.sum())
    mass_ok = (1.0 - damping) - tol <= mass <= 1.0 + tol
    checks.append(CheckResult("mass_conservation", mass_ok,
                              violations=0 if mass_ok else 1,
                              detail=f"mass={mass:.6f}"))
    # Residual bound: the damped map is a d-contraction in l1, so after k
    # iterations one more step moves the vector by at most 2·d^k.
    deg = g.out_degrees().astype(np.float64)
    inv = np.where(deg > 0, 1.0 / np.maximum(deg, 1.0), 0.0)
    src = params.get("_src")
    src = g.edge_sources() if src is None else src
    push = (rank * inv)[src]
    acc = np.zeros(n, dtype=np.float64)
    np.add.at(acc, g.col, push)
    nxt = (1.0 - damping) / n + damping * acc
    resid = float(np.abs(nxt - rank).sum())
    bound = 2.0 * damping ** int(num_iterations) + tol
    checks.append(CheckResult("residual_bound", resid <= bound,
                              violations=0 if resid <= bound else 1,
                              detail=f"l1 residual {resid:.3e} > bound "
                                     f"{bound:.3e}" if resid > bound else
                                     f"l1 residual {resid:.3e}"))
    return checks


@register_certifier("bc")
def _certify_bc(g, bc, source=None, sample=512, rtol=1e-3, atol=1e-4,
                seed=0, **params):
    """Sampled pair-recomputation: Brandes' single-source pass is itself
    O(V+E), so the certificate is a reference recompute compared at a
    deterministic vertex sample (all vertices on small graphs)."""
    if source is None:
        raise ValueError("bc certifier needs the query source vertex")
    from repro.algorithms.bc import bc_reference
    bc = np.asarray(bc, dtype=np.float64)
    ref = np.asarray(bc_reference(g, int(source)), dtype=np.float64)
    n = g.num_vertices
    if n <= sample:
        idx = np.arange(n)
    else:
        rng = np.random.default_rng(seed + int(source))
        idx = np.unique(np.concatenate([
            rng.choice(n, size=sample, replace=False),
            np.argsort(ref)[-16:],          # always check the heavy hitters
        ]))
    err = np.abs(bc[idx] - ref[idx])
    bad = err > (atol + rtol * np.abs(ref[idx]))
    detail = ""
    if bad.any():
        worst = idx[int(np.argmax(err))]
        detail = (f"vertex {int(worst)}: got {bc[worst]:.5f} "
                  f"want {ref[worst]:.5f}")
    return [_check("pair_recompute", bad, detail=detail),
            _check("finite_nonneg", ~np.isfinite(bc) | (bc < -1e-6))]


# ---------------------------------------------------------------------------
# public certifier handle


class ResultCertifier:
    """Certifier bound to one graph: ``certify(result, source)`` -> Verdict.

    Also owns the recompute-once policy's reference oracle: ``recompute``
    returns the trusted NumPy answer for one query so the serving layer can
    distinguish a corrupted-but-retryable result from a persistent fault.
    """

    def __init__(self, algorithm: str, g, **params):
        if algorithm not in _CERTIFIERS:
            raise ValueError(
                f"no certifier registered for {algorithm!r}; "
                f"known: {registered_algorithms()}")
        self.algorithm = algorithm
        self.g = g
        self.params = params
        # edge_sources() is an O(E) np.repeat with no caching on the graph;
        # a bound certifier runs once per query, so expand it exactly once.
        self._src = None

    def _edge_src(self) -> np.ndarray:
        if self._src is None:
            self._src = np.asarray(self.g.edge_sources())
        return self._src

    def certify(self, result, source: Optional[int] = None) -> Verdict:
        # inf/NaN are expected *inputs* (unreached vertices, poisoned
        # states); the checks classify them, so numpy's arithmetic
        # warnings on non-finite intermediates are noise here
        with np.errstate(invalid="ignore"):
            checks = tuple(_CERTIFIERS[self.algorithm](
                self.g, np.asarray(result), source=source,
                _src=self._edge_src(), **self.params))
        return Verdict(algorithm=self.algorithm,
                       ok=all(c.ok for c in checks), checks=checks)

    def certify_batch(self, results,
                      sources: Optional[Sequence[int]] = None) -> List[Verdict]:
        rows = np.asarray(results)
        if rows.ndim == 1:
            rows = rows[None]
        srcs = list(sources) if sources is not None else [None] * len(rows)
        return [self.certify(row, src) for row, src in zip(rows, srcs)]

    def recompute(self, source: Optional[int] = None) -> np.ndarray:
        """Trusted reference answer for one query (NumPy, engine-free)."""
        alg = self.algorithm
        if alg == "bfs":
            from repro.algorithms.bfs import bfs_reference
            return bfs_reference(self.g, int(source))
        if alg == "sssp":
            from repro.algorithms.sssp import sssp_reference
            return sssp_reference(self.g, int(source))
        if alg == "cc":
            from repro.algorithms.cc import cc_reference
            return cc_reference(self.g)
        if alg == "pagerank":
            from repro.algorithms.pagerank import pagerank_reference
            return np.asarray(pagerank_reference(
                self.g,
                num_iterations=self.params.get("num_iterations", 20),
                damping=self.params.get("damping", 0.85)))
        if alg == "bc":
            from repro.algorithms.bc import bc_reference
            return bc_reference(self.g, int(source))
        raise ValueError(f"no reference oracle for {alg!r}")


def certify(algorithm: str, g, result, source: Optional[int] = None,
            **params) -> Verdict:
    """One-shot convenience: ``certify('bfs', g, levels, source=0)``."""
    return ResultCertifier(algorithm, g, **params).certify(result, source)


# ---------------------------------------------------------------------------
# in-loop invariant monitor (window-boundary observer, pure host NumPy)


_MONITOR_KEYS = {
    # keys monitored per algorithm; combine decides finiteness semantics.
    "bfs": (("level",), "min"),
    "sssp": (("dist",), "min"),
    "cc": (("label",), "min"),
    "pagerank": (("rank",), "sum"),
    # BC's forward dist legitimately holds inf for unreached vertices, so
    # only the sum-accumulated leaves are finiteness-checked.
    "bc": (("sigma",), "sum"),
}


def monitor_for(algorithm: str, chunk: Optional[int] = None) -> "InvariantMonitor":
    if algorithm not in _MONITOR_KEYS:
        raise ValueError(f"no monitor profile for {algorithm!r}; "
                         f"known: {sorted(_MONITOR_KEYS)}")
    keys, combine = _MONITOR_KEYS[algorithm]
    return InvariantMonitor(keys=keys, combine=combine, chunk=chunk)


class InvariantMonitor:
    """Cross-window invariant observer for the chunked superstep loop.

    ``run_batched_chunked`` calls :meth:`observe` once per window with the
    same snapshot it hands ``on_chunk`` (state / fin / steps_q / step), and
    :meth:`rebase` after a slot refill so admitted slots get fresh
    baselines instead of firing spurious monotonicity violations.  All
    checks are host-side NumPy on the already-materialized snapshot — they
    add no traced ops to the compiled window.

    Checks per window:

    * finiteness — semiring-aware (sum: any non-finite; min: NaN/-inf —
      +inf is the legal "unreached" value), scoped to *unfinished* slots so
      NaN-frozen quarantined slots don't re-fire every window;
    * monotonicity (min combines only) — monitored leaves never increase
      across windows on surviving slots;
    * frontier sanity — finished votes never regress and per-slot step
      counters advance by a non-negative amount bounded by the chunk size.
    """

    def __init__(self, keys: Sequence[str], combine: str = "min",
                 chunk: Optional[int] = None):
        self.keys = tuple(keys)
        self.combine = combine
        self.chunk = None if chunk is None else int(chunk)
        self.windows = 0
        self.fired: List[dict] = []
        self._prev: Optional[Dict[str, np.ndarray]] = None
        self._prev_fin: Optional[np.ndarray] = None
        self._prev_steps: Optional[np.ndarray] = None
        self._skip: Optional[np.ndarray] = None   # slots refilled last window

    @property
    def violations(self) -> int:
        return sum(rec["violations"] for rec in self.fired)

    def rebase(self, admit) -> None:
        """Mark slots refilled this window: their next-window comparison
        against the pre-refill baseline would be meaningless."""
        admit = np.asarray(admit, dtype=bool)
        if self._skip is None:
            self._skip = admit.copy()
        else:
            self._skip = self._skip | admit

    def observe(self, snap: dict) -> dict:
        state = snap["state"]
        fin = np.asarray(snap["finished"], dtype=bool).reshape(-1)
        steps_q = np.asarray(snap["steps_q"]).reshape(-1)
        # non-finite values are expected *input* here (they're what the
        # finiteness check hunts), so numpy's cast/compare warnings are noise
        with np.errstate(invalid="ignore"):
            cur = {k: np.asarray(np.asarray(state[k]), dtype=np.float64)
                   for k in self.keys if k in state}
        q = fin.shape[0]
        skip = (self._skip if self._skip is not None
                else np.zeros(q, dtype=bool))
        found: List[dict] = []

        for key, arr in cur.items():
            flat = arr.reshape(arr.shape[0], -1)
            if self.combine == "sum":
                bad = ~np.isfinite(flat)
            else:
                bad = np.isnan(flat) | np.isneginf(flat)
            slots = bad.any(axis=1) & ~fin
            if slots.any():
                found.append(dict(check="finiteness", key=key,
                                  slots=np.flatnonzero(slots).tolist()))
            if (self.combine == "min" and self._prev is not None
                    and key in self._prev
                    and self._prev[key].shape == flat.shape[0:1] + (flat.shape[1],)):
                # NaN comparisons are False, so poisoned slots surface via
                # the finiteness check above, not a spurious increase here.
                inc = (flat > self._prev[key]).any(axis=1) & ~skip
                if inc.any():
                    found.append(dict(check="monotonicity", key=key,
                                      slots=np.flatnonzero(inc).tolist()))
            cur[key] = flat

        if self._prev_fin is not None and self._prev_fin.shape == fin.shape:
            regressed = self._prev_fin & ~fin & ~skip
            if regressed.any():
                found.append(dict(check="finished_regressed",
                                  slots=np.flatnonzero(regressed).tolist()))
        if self._prev_steps is not None and self._prev_steps.shape == steps_q.shape:
            delta = steps_q - self._prev_steps
            bad_d = (delta < 0) & ~skip
            if self.chunk is not None:
                bad_d |= (delta > self.chunk) & ~skip
            if bad_d.any():
                found.append(dict(check="steps_delta",
                                  slots=np.flatnonzero(bad_d).tolist()))

        self._prev = cur
        self._prev_fin = fin.copy()
        self._prev_steps = steps_q.copy()
        self._skip = None
        self.windows += 1
        rec = dict(step=int(snap.get("step", -1)), violations=len(found),
                   checks=found)
        if found:
            self.fired.append(rec)
        return rec
