from repro.runtime.watchdog import StepWatchdog
from repro.runtime.failures import run_with_restarts, FaultInjector

__all__ = ["StepWatchdog", "run_with_restarts", "FaultInjector"]
