from repro.runtime.watchdog import StepWatchdog
from repro.runtime.failures import (
    run_with_restarts, serve_with_restarts, FaultInjector, WorkerFailure,
    RestartPolicy, RETRYABLE_EXCEPTIONS)
from repro.runtime.sla import (
    AdmissionController, QuarantinePolicy, DegradationLadder)
from repro.runtime.session import ServeSession, drain_reference
from repro.runtime import chaos

__all__ = [
    "StepWatchdog", "run_with_restarts", "serve_with_restarts",
    "FaultInjector", "WorkerFailure", "RestartPolicy",
    "RETRYABLE_EXCEPTIONS", "AdmissionController", "QuarantinePolicy",
    "DegradationLadder", "ServeSession", "drain_reference", "chaos",
]
