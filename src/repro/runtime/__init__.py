from repro.runtime.watchdog import StepWatchdog
from repro.runtime.failures import (
    run_with_restarts, serve_with_restarts, FaultInjector, WorkerFailure,
    ExchangeCorruption, RestartPolicy, RETRYABLE_EXCEPTIONS)
from repro.runtime.sla import (
    AdmissionController, QuarantinePolicy, DegradationLadder,
    nonfinite_queries)
from repro.runtime.session import ServeSession, drain_reference
from repro.runtime.verify import (
    CheckResult, Verdict, ResultCertifier, InvariantMonitor, certify,
    monitor_for)
from repro.runtime import chaos

__all__ = [
    "StepWatchdog", "run_with_restarts", "serve_with_restarts",
    "FaultInjector", "WorkerFailure", "ExchangeCorruption", "RestartPolicy",
    "RETRYABLE_EXCEPTIONS", "AdmissionController", "QuarantinePolicy",
    "DegradationLadder", "ServeSession", "drain_reference", "chaos",
    "CheckResult", "Verdict", "ResultCertifier", "InvariantMonitor",
    "certify", "monitor_for", "nonfinite_queries",
]
