"""Gate on superstep-benchmark regressions.

Diffs a fresh ``BENCH_superstep.json`` (benchmarks/superstep_bench.py)
against a baseline run and fails when any matching cell's fused superstep
time — or any ``--extra-timing-fields`` metric present on both sides, e.g.
the batched column's amortized ``batched_ms_per_query`` — regressed by
more than ``--threshold`` (default 20%), or when any *deterministic*
metric (``--byte-fields``: per-superstep exchanged bytes, fused temp
bytes, and the batched column's compile-cache ``retraces``, which must
stay at 0 — any growth from 0 fails the ratio gate outright) grew by more
than ``--byte-threshold`` (20%) — deterministic counts don't suffer
interpret-mode timing noise, so their gate stays tight even when the
timing threshold is widened for CI.  The make/CI entry point:

  python benchmarks/superstep_bench.py --quick --out BENCH_superstep.json
  python scripts/bench_check.py BENCH_superstep.json \
      --baseline BENCH_superstep.prev.json --seed-missing

``--baseline`` names the comparison file (no hardcoding, so CI can point at
a cache-restored path); ``--seed-missing`` copies the current run into the
baseline slot and passes when no baseline exists yet (first run on a fresh
cache/checkout).  Cells are matched on (scale, parts, strategy, algorithm,
block_e); cells present on only one side are reported but don't fail the
check (benchmarks grow over time).  Exit codes: 0 ok, 1 regression, 2
usage/IO error.
"""
from __future__ import annotations

import argparse
import json
import shutil
import sys
from pathlib import Path


def _key(rec: dict):
    # None-valued fields become sort-safe sentinels (distributed cells have
    # no block_e; legacy baselines have no mode).
    return (rec["scale"], rec["parts"], rec["strategy"], rec["algorithm"],
            rec.get("block_e") or 0, rec.get("mode") or "")


def load(path: str) -> dict:
    try:
        data = json.loads(Path(path).read_text())
    except (OSError, json.JSONDecodeError) as e:
        print(f"bench_check: cannot read {path}: {e}", file=sys.stderr)
        sys.exit(2)
    return {_key(r): r for r in data.get("results", [])}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("current", nargs="?", default="BENCH_superstep.json",
                    help="fresh BENCH_superstep.json")
    ap.add_argument("--baseline", default="BENCH_superstep.prev.json",
                    help="baseline BENCH_superstep.json to compare against")
    ap.add_argument("--seed-missing", action="store_true",
                    help="seed the baseline from the current run (and pass) "
                         "when the baseline file does not exist")
    ap.add_argument("--threshold", type=float, default=0.20,
                    help="max allowed fractional regression")
    ap.add_argument("--field", default="fused_ms",
                    help="which per-cell timing to gate on")
    ap.add_argument("--extra-timing-fields", nargs="*",
                    default=["batched_ms_per_query", "certify_ms",
                             "verify_overhead_ratio"],
                    help="additional timing metrics gated at --threshold "
                         "when present on both sides (batched cells carry "
                         "these instead of --field; verify cells carry the "
                         "certifier cost and its overhead ratio)")
    ap.add_argument("--byte-fields", nargs="*",
                    default=["exchanged_bytes", "fused_temp_bytes",
                             "retraces", "incremental_steps", "cold_steps",
                             "quarantined", "chunk_retraces", "refills",
                             "windows", "monitors_fired",
                             "hbm_resident_bytes", "host_bytes",
                             "streamed_bytes_per_superstep", "window_count",
                             "topdown_edges", "dopt_edges", "dopt_switches"],
                    help="deterministic metrics gated at --byte-threshold "
                         "regardless of timing noise (retraces must stay "
                         "0: any growth fails; the mutation column's "
                         "superstep counts, the checkpoint column's "
                         "clean-path quarantine/retrace counts, and the "
                         "continuous column's refill/window counts are "
                         "superstep-indexed and deterministic too; the "
                         "verify column's monitor-fire count must stay 0; "
                         "the oocore column's arena/stream byte fields and "
                         "window count are plan-deterministic for a pinned "
                         "seed; the dopt column's edges-examined and "
                         "switch counters are superstep-indexed int32 sums "
                         "— a growing dopt_edges means the direction vote "
                         "got lazier)")
    ap.add_argument("--byte-threshold", type=float, default=0.20,
                    help="max allowed fractional growth in --byte-fields")
    args = ap.parse_args(argv)

    if not Path(args.baseline).exists():
        if args.seed_missing:
            if not Path(args.current).exists():
                print(f"bench_check: {args.current} missing, cannot seed",
                      file=sys.stderr)
                return 2
            shutil.copyfile(args.current, args.baseline)
            print(f"bench_check: seeded baseline {args.baseline} from "
                  f"{args.current}")
            return 0
        print(f"bench_check: baseline {args.baseline} missing "
              f"(run with --seed-missing to create it)", file=sys.stderr)
        return 2

    cur, prev = load(args.current), load(args.baseline)
    regressions, checked = [], 0
    for key, rec in sorted(cur.items()):
        base = prev.get(key)
        if base is None:
            print(f"  new/unmatched cell (not gated): {key}")
            continue
        for field in [args.field] + list(args.extra_timing_fields):
            if base.get(field) is None or rec.get(field) is None:
                continue
            checked += 1
            ratio = rec[field] / max(base[field], 1e-12)
            status = "OK"
            if ratio > 1.0 + args.threshold:
                status = "REGRESSION"
                regressions.append((key, field, ratio))
            print(f"  {key}: {field} {base[field]:.2f} -> "
                  f"{rec[field]:.2f} ms ({ratio:.2f}x) {status}")
        # Deterministic byte metrics: gate growth tightly (no timing noise).
        for field in args.byte_fields:
            if base.get(field) is None or rec.get(field) is None:
                continue
            checked += 1
            ratio = rec[field] / max(base[field], 1e-12)
            status = "OK"
            if ratio > 1.0 + args.byte_threshold:
                status = "REGRESSION"
                regressions.append((key, field, ratio))
            print(f"  {key}: {field} {base[field]} -> {rec[field]} B "
                  f"({ratio:.2f}x) {status}")

    dropped = set(prev) - set(cur)
    for key in sorted(dropped):
        print(f"  cell disappeared (not gated): {key}")

    if regressions:
        for key, field, ratio in regressions:
            print(f"bench_check: {key} regressed {ratio:.2f}x on {field}",
                  file=sys.stderr)
        print(f"bench_check: {len(regressions)}/{checked} gated metrics "
              f"regressed", file=sys.stderr)
        return 1
    print(f"bench_check: {checked} gated metrics within thresholds "
          f"(timing {args.threshold:.0%}, bytes {args.byte_threshold:.0%})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
