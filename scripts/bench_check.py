"""Gate on superstep-benchmark regressions.

Diffs a fresh ``BENCH_superstep.json`` (benchmarks/superstep_bench.py)
against a baseline run and fails when any matching cell's fused superstep
time regressed by more than ``--threshold`` (default 20%).  The make/CI
entry point:

  python benchmarks/superstep_bench.py --quick --out BENCH_superstep.json
  python scripts/bench_check.py BENCH_superstep.json \
      --baseline BENCH_superstep.prev.json --seed-missing

``--baseline`` names the comparison file (no hardcoding, so CI can point at
a cache-restored path); ``--seed-missing`` copies the current run into the
baseline slot and passes when no baseline exists yet (first run on a fresh
cache/checkout).  Cells are matched on (scale, parts, strategy, algorithm,
block_e); cells present on only one side are reported but don't fail the
check (benchmarks grow over time).  Exit codes: 0 ok, 1 regression, 2
usage/IO error.
"""
from __future__ import annotations

import argparse
import json
import shutil
import sys
from pathlib import Path


def _key(rec: dict):
    return (rec["scale"], rec["parts"], rec["strategy"], rec["algorithm"],
            rec.get("block_e"))


def load(path: str) -> dict:
    try:
        data = json.loads(Path(path).read_text())
    except (OSError, json.JSONDecodeError) as e:
        print(f"bench_check: cannot read {path}: {e}", file=sys.stderr)
        sys.exit(2)
    return {_key(r): r for r in data.get("results", [])}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("current", nargs="?", default="BENCH_superstep.json",
                    help="fresh BENCH_superstep.json")
    ap.add_argument("--baseline", default="BENCH_superstep.prev.json",
                    help="baseline BENCH_superstep.json to compare against")
    ap.add_argument("--seed-missing", action="store_true",
                    help="seed the baseline from the current run (and pass) "
                         "when the baseline file does not exist")
    ap.add_argument("--threshold", type=float, default=0.20,
                    help="max allowed fractional regression")
    ap.add_argument("--field", default="fused_ms",
                    help="which per-cell timing to gate on")
    args = ap.parse_args(argv)

    if not Path(args.baseline).exists():
        if args.seed_missing:
            if not Path(args.current).exists():
                print(f"bench_check: {args.current} missing, cannot seed",
                      file=sys.stderr)
                return 2
            shutil.copyfile(args.current, args.baseline)
            print(f"bench_check: seeded baseline {args.baseline} from "
                  f"{args.current}")
            return 0
        print(f"bench_check: baseline {args.baseline} missing "
              f"(run with --seed-missing to create it)", file=sys.stderr)
        return 2

    cur, prev = load(args.current), load(args.baseline)
    regressions, checked = [], 0
    for key, rec in sorted(cur.items()):
        base = prev.get(key)
        if base is None or args.field not in base or args.field not in rec:
            print(f"  new/unmatched cell (not gated): {key}")
            continue
        checked += 1
        ratio = rec[args.field] / max(base[args.field], 1e-12)
        status = "OK"
        if ratio > 1.0 + args.threshold:
            status = "REGRESSION"
            regressions.append((key, ratio))
        print(f"  {key}: {args.field} {base[args.field]:.2f} -> "
              f"{rec[args.field]:.2f} ms ({ratio:.2f}x) {status}")

    dropped = set(prev) - set(cur)
    for key in sorted(dropped):
        print(f"  cell disappeared (not gated): {key}")

    if regressions:
        print(f"bench_check: {len(regressions)}/{checked} cells regressed "
              f">{args.threshold:.0%} on {args.field}", file=sys.stderr)
        return 1
    print(f"bench_check: {checked} cells within {args.threshold:.0%}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
