"""Gate on superstep-benchmark regressions.

Diffs a fresh ``BENCH_superstep.json`` (benchmarks/superstep_bench.py)
against a previous run and fails when any matching cell's fused superstep
time regressed by more than ``--threshold`` (default 20%).  Intended as an
optional make/CI target:

  python benchmarks/superstep_bench.py --out BENCH_superstep.json
  python scripts/bench_check.py BENCH_superstep.json BENCH_superstep.prev.json

Cells are matched on (scale, parts, strategy, algorithm, block_e); cells
present on only one side are reported but don't fail the check (benchmarks
grow over time).  Exit codes: 0 ok, 1 regression, 2 usage/IO error.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path


def _key(rec: dict):
    return (rec["scale"], rec["parts"], rec["strategy"], rec["algorithm"],
            rec.get("block_e"))


def load(path: str) -> dict:
    try:
        data = json.loads(Path(path).read_text())
    except (OSError, json.JSONDecodeError) as e:
        print(f"bench_check: cannot read {path}: {e}", file=sys.stderr)
        sys.exit(2)
    return {_key(r): r for r in data.get("results", [])}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("current", help="fresh BENCH_superstep.json")
    ap.add_argument("previous", help="baseline BENCH_superstep.json")
    ap.add_argument("--threshold", type=float, default=0.20,
                    help="max allowed fractional fused_ms regression")
    ap.add_argument("--field", default="fused_ms",
                    help="which per-cell timing to gate on")
    args = ap.parse_args(argv)

    cur, prev = load(args.current), load(args.previous)
    regressions, checked = [], 0
    for key, rec in sorted(cur.items()):
        base = prev.get(key)
        if base is None or args.field not in base or args.field not in rec:
            print(f"  new/unmatched cell (not gated): {key}")
            continue
        checked += 1
        ratio = rec[args.field] / max(base[args.field], 1e-12)
        status = "OK"
        if ratio > 1.0 + args.threshold:
            status = "REGRESSION"
            regressions.append((key, ratio))
        print(f"  {key}: {args.field} {base[args.field]:.2f} -> "
              f"{rec[args.field]:.2f} ms ({ratio:.2f}x) {status}")

    dropped = set(prev) - set(cur)
    for key in sorted(dropped):
        print(f"  cell disappeared (not gated): {key}")

    if regressions:
        print(f"bench_check: {len(regressions)}/{checked} cells regressed "
              f">{args.threshold:.0%} on {args.field}", file=sys.stderr)
        return 1
    print(f"bench_check: {checked} cells within {args.threshold:.0%}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
